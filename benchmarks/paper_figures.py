"""One benchmark per paper table/figure (DESIGN.md §7 index).

Figures 7/8/9/10/11/12 compare the PK overlapped schedule against the
non-overlapped bulk baseline on emulated devices; Table 3 and Figures 2/3/6
are reproduced analytically from the cost model with the v5e constants
(hardware-bound quantities that cannot be measured on CPU) alongside the
emulated-relative timings.
"""

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import make_mesh, pred_hw, row, smap, timeit
from repro.core import costmodel as cm
from repro.core import (pk_moe_a2a, pk_ring_attention, pk_ulysses_attention,
                        ring_attention_baseline)
from repro.core.comms import CommContext, GEMM_OP_KIND
from repro.core.template import Comm, Island

N = 8

# All collectives go through the unified CommContext; benchmarks pin the
# backend explicitly (backend="ring" vs "bulk") to measure both sides of
# each paper figure instead of letting the cost-model policy decide. The
# GEMM×collective figures are declared as core.template Islands — the same
# scaffold the model stack runs through.
CTX = CommContext(axis_name="x")


def fig2_3_transfer_granularity():
    """Paper Fig. 2/3: transfer-mechanism granularity/saturation — on TPU the
    mechanisms are XLA bulk collectives (copy-engine analogue) vs in-kernel
    RDMA (TMA analogue). Analytic: message size needed to reach 80% of link
    bandwidth given per-transfer setup latency."""
    setup_bulk = 20e-6      # host-scheduled collective launch overhead
    setup_rdma = 1e-6       # device-initiated descriptor issue
    for mb in (0.002, 0.032, 0.256, 2, 16, 256):
        nbytes = mb * 2 ** 20
        for name, setup in (("xla_bulk", setup_bulk), ("pk_rdma", setup_rdma)):
            t = nbytes / cm.TPU_V5E.ici_bandwidth + setup
            eff = (nbytes / cm.TPU_V5E.ici_bandwidth) / t
            row(f"fig2_granularity/{name}/{mb}MB", t * 1e6,
                f"link_util={eff:.2f}")


def table3_hiding_threshold():
    """Paper Table 3: GEMM+RS comm ratio vs K. Analytic with v5e constants
    (paper derives K*>=2197 on H100; v5e ring: K*>=3940 per link-pair)."""
    for hwname, hw in (("h100", cm.H100_SXM), ("v5e", cm.TPU_V5E)):
        kstar = cm.hiding_threshold_k(2, hw)
        row(f"table3_threshold/{hwname}", 0.0, f"K*={kstar}")
    m = n = 32768
    for k in (512, 1024, 2048, 4096, 8192):
        c = cm.overlapped_gemm_collective_cost(m, n, k, axis_size=8,
                                               kind="reduce_scatter",
                                               n_chunks=8)
        ratio = max(0.0, (c.t_comm - c.t_comp) / c.total)
        row(f"table3_gemm_rs/K={k}", c.total * 1e6,
            f"nonoverlapped_comm_ratio={ratio:.2f}")


def fig6_allreduce_design_overhead():
    """Paper Fig. 6: one-way pre-allocated-buffer AR vs two-way-sync AR.
    Emulated timing: XLA psum vs decomposed ring (ppermute RS+AG) vs the
    analytic sync-overhead model (64 ns local vs 832 ns remote per paper)."""
    mesh = make_mesh()
    hw = pred_hw()
    for size_kb in (64, 1024, 8192):
        n_el = size_kb * 1024 // 4
        x = jax.random.normal(jax.random.PRNGKey(0), (N, n_el))
        t_xfer = cm.transfer_cost(
            cm.ring_collective_bytes(size_kb * 1024, N, "all_reduce"), hw)
        f_bulk = smap(mesh, lambda x: CTX.psum(x[0], backend="bulk")[None],
                      P("x"), P("x"))
        us = timeit(f_bulk, x)
        row(f"fig6_allreduce/xla_psum/{size_kb}KB", us, "",
            predicted_us=(hw.kernel_launch_s + t_xfer
                          + (N - 1) * hw.remote_sync_s) * 1e6)

        f_ring = smap(mesh, lambda x: CTX.psum(x[0], backend="ring")[None],
                      P("x"), P("x"))
        us2 = timeit(f_ring, x)
        row(f"fig6_allreduce/pk_ring/{size_kb}KB", us2,
            f"vs_bulk={us/max(us2,1e-9):.2f}x",
            predicted_us=(hw.kernel_launch_s + t_xfer
                          + 2 * (N - 1) * hw.remote_sync_s) * 1e6)
    # sync-cost asymmetry (paper: 64 ns mbarrier vs 832 ns HBM flag)
    row("fig6_sync/local_ns", cm.TPU_V5E.local_sync_s * 1e6, "per_sync")
    row("fig6_sync/remote_ns", cm.TPU_V5E.remote_sync_s * 1e6, "per_sync")


_OP_KIND = GEMM_OP_KIND           # op -> cost-model kind, shared with comms


def _gemm_shape(op, x, w):
    """Dispatch-coordinate (m, n, k) of the GEMM a figure actually runs,
    derived from the real operand arrays (x row-sharded for AG, K-sharded
    for RS/AR) so predictions can never drift from the measured shapes."""
    if op == "all_gather_matmul":
        return x.shape[0], w.shape[1], x.shape[1]
    return x.shape[0], w.shape[1], x.shape[1] // N   # local K shard


def _gemm_island(mesh, tag, op, backend, in_specs, out_specs, m, n, k):
    """One GEMM×collective figure side as a declared unified-template
    Island — the same scaffold the model stack runs through — with the
    backend pinned per call (measuring both sides of the paper figure)."""
    island = Island(
        f"{tag}/{backend}", mesh=mesh, axis="x",
        inputs={"x": in_specs[0], "w": in_specs[1]}, out_specs=out_specs,
        body=lambda ctx, x, w: getattr(ctx, op)(x, w, backend=backend),
        comm=Comm(op, m=m, n=n, k=k, backend=backend))
    return jax.jit(lambda x, w: island(x=x, w=w))


def _gemm_overlap_bench(tag, op, in_specs, out_specs, make_args, *,
                        overlap_backend="ring"):
    mesh = make_mesh()
    hw = pred_hw()
    kind = _OP_KIND[op]
    for nsz in (512, 1024, 2048):
        args = make_args(nsz)
        m, n, k = _gemm_shape(op, *args)
        pred_pk = cm.overlapped_gemm_collective_cost(
            m, n, k, axis_size=N, kind=kind, n_chunks=N, hw=hw).total
        pred_b = cm.bulk_gemm_collective_cost(
            m, n, k, axis_size=N, kind=kind, hw=hw).total
        f_pk = _gemm_island(mesh, tag, op, overlap_backend, in_specs,
                            out_specs, m, n, k)
        f_b = _gemm_island(mesh, tag, op, "bulk", in_specs, out_specs,
                           m, n, k)
        us_pk = timeit(f_pk, *args)
        us_b = timeit(f_b, *args)
        row(f"{tag}/pk/N={nsz}", us_pk, f"speedup={us_b/max(us_pk,1e-9):.2f}x",
            predicted_us=pred_pk * 1e6)
        row(f"{tag}/baseline/N={nsz}", us_b, "", predicted_us=pred_b * 1e6)


def fig7_ag_gemm():
    """Paper Fig. 7: AG+GEMM, local shape (N x N/8 x N)."""
    def make(nsz):
        x = jax.random.normal(jax.random.PRNGKey(0), (nsz, nsz // 4),
                              jnp.bfloat16)
        w = jax.random.normal(jax.random.PRNGKey(1), (nsz // 4, nsz // 4),
                              jnp.bfloat16)
        return x, w
    _gemm_overlap_bench("fig7_ag_gemm", "all_gather_matmul",
                        (P("x"), P()), P(), make)


def fig8_gemm_rs():
    """Paper Fig. 8: GEMM+RS, local shape (N x N x N/8)."""
    def make(nsz):
        x = jax.random.normal(jax.random.PRNGKey(0), (nsz, N * (nsz // 8)),
                              jnp.bfloat16)
        w = jax.random.normal(jax.random.PRNGKey(1),
                              (N * (nsz // 8), nsz // 4), jnp.bfloat16)
        return x, w
    _gemm_overlap_bench("fig8_gemm_rs", "matmul_reduce_scatter",
                        (P(None, "x"), P("x", None)), P("x", None), make)


def fig9_gemm_ar():
    """Paper Fig. 9: GEMM+AR (no in-network reduction on ICI: RS∘AG)."""
    def make(nsz):
        x = jax.random.normal(jax.random.PRNGKey(0), (nsz, N * (nsz // 8)),
                              jnp.bfloat16)
        w = jax.random.normal(jax.random.PRNGKey(1),
                              (N * (nsz // 8), nsz // 4), jnp.bfloat16)
        return x, w
    _gemm_overlap_bench("fig9_gemm_ar", "matmul_all_reduce",
                        (P(None, "x"), P("x", None)), P(), make)


def fig10_ring_attention():
    """Paper Fig. 10: ring attention vs bulk-allgather attention."""
    mesh = make_mesh()
    for s_total in (2048, 4096, 8192):
        b, hq, hkv, d = 1, 8, 2, 64
        q = jax.random.normal(jax.random.PRNGKey(0), (b, hq, s_total, d),
                              jnp.bfloat16)
        k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s_total, d),
                              jnp.bfloat16)
        v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s_total, d),
                              jnp.bfloat16)
        sp = (P(None, None, "x"),) * 3
        f_pk = smap(mesh, lambda q, k, v: pk_ring_attention(q, k, v, "x"),
                    sp, P(None, None, "x"))
        f_b = smap(mesh, lambda q, k, v: ring_attention_baseline(q, k, v, "x"),
                   sp, P(None, None, "x"))
        us_pk = timeit(f_pk, q, k, v)
        us_b = timeit(f_b, q, k, v)
        row(f"fig10_ring_attn/pk/S={s_total}", us_pk,
            f"speedup={us_b/max(us_pk,1e-9):.2f}x")
        row(f"fig10_ring_attn/baseline/S={s_total}", us_b, "")


def fig11_ulysses():
    """Paper Fig. 11: Ulysses a2a attention — chunked vs bulk a2a."""
    mesh = make_mesh()
    for s_total in (2048, 4096):
        b, hq, hkv, d = 1, 16, 8, 64
        q = jax.random.normal(jax.random.PRNGKey(0), (b, hq, s_total, d),
                              jnp.bfloat16)
        k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s_total, d),
                              jnp.bfloat16)
        v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s_total, d),
                              jnp.bfloat16)
        sp = (P(None, None, "x"),) * 3
        for nc in (1, 2):
            f = smap(mesh, lambda q, k, v, nc=nc: pk_ulysses_attention(
                q, k, v, "x", n_chunks=nc), sp, P(None, None, "x"))
            us = timeit(f, q, k, v)
            row(f"fig11_ulysses/chunks={nc}/S={s_total}", us, "")


def fig12_moe_dispatch():
    """Paper Fig. 12: expert-parallel dispatch+GEMM, chunked overlap vs bulk
    (Comet comparison)."""
    mesh = make_mesh()
    t, d, ff, e, k = 1024, 256, 512, 8, 2
    x = jax.random.normal(jax.random.PRNGKey(0), (N * t, d), jnp.bfloat16)
    wr = jax.random.normal(jax.random.PRNGKey(1), (d, e))
    w1 = jax.random.normal(jax.random.PRNGKey(2), (N, 1, d, ff), jnp.bfloat16)
    w3 = jax.random.normal(jax.random.PRNGKey(3), (N, 1, d, ff), jnp.bfloat16)
    w2 = jax.random.normal(jax.random.PRNGKey(4), (N, 1, ff, d), jnp.bfloat16)
    for nc in (1, 2, 4):
        f = smap(mesh, lambda x, wr, a, b, c, nc=nc: pk_moe_a2a(
            x, wr, a[0], b[0], c[0], axis_name="x", n_experts=e, top_k=k,
            n_chunks=nc)[0],
            (P("x"), P(), P("x"), P("x"), P("x")), P("x"))
        us = timeit(f, x, wr, w1, w3, w2)
        row(f"fig12_moe_dispatch/chunks={nc}", us,
            f"tokens={N*t}")


def fig15_17_strided_collectives():
    """Paper Fig. 15/16/17 (App. B): collectives on the tensor (last) dim —
    strided layouts that NCCL needs staging copies for; lax handles natively
    and PK chunking overlaps."""
    mesh = make_mesh()
    for nsz in (512, 1024):
        x = jax.random.normal(jax.random.PRNGKey(0), (nsz, nsz), jnp.bfloat16)
        f_ag = smap(mesh, lambda x: jax.lax.all_gather(x, "x", axis=1,
                                                       tiled=True),
                    P(None, "x"), P())
        row(f"fig15_tensor_dim_ag/N={nsz}", timeit(f_ag, x), "")
        f_rs = smap(mesh, lambda x: jax.lax.psum_scatter(
            x, "x", scatter_dimension=1, tiled=True), P(), P(None, "x"))
        row(f"fig16_tensor_dim_rs/N={nsz}", timeit(f_rs, x), "")
        xa = jax.random.normal(jax.random.PRNGKey(1), (1, nsz, 16, 64),
                               jnp.bfloat16)
        f_a2a = smap(mesh, lambda x: CTX.all_to_all(x, split_axis=2,
                                                    concat_axis=1),
                     P(None, "x"), P(None, None, "x"))
        row(f"fig17_4d_a2a/S={nsz}", timeit(f_a2a, xa), "")


def fig_unified_template():
    """Paper §3.2 (the unified template claim): the model stack's MLP island
    declared through core.template vs its dense reference, plus the
    trace-free plan() line for every island of a forward pass (backend /
    chunks / predicted hidden fraction)."""
    from repro.configs import get_config
    from repro.configs.base import RunConfig
    from repro.models import layers as L
    from repro.models.sharding import ShardingRules

    mesh = make_mesh((1, 8), ("data", "x"))
    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), tp_axis="x", fsdp=False)
    rules = ShardingRules(mesh, run)
    b, s, d, ff = 8, 64, cfg.d_model, cfg.d_ff
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, s, d), jnp.bfloat16)
    p = {"w1": jax.random.normal(jax.random.PRNGKey(1), (d, ff), jnp.bfloat16),
         "w3": jax.random.normal(jax.random.PRNGKey(2), (d, ff), jnp.bfloat16),
         "w2": jax.random.normal(jax.random.PRNGKey(3), (ff, d), jnp.bfloat16)}

    f_pk = jax.jit(lambda x, p: L.mlp_block(p, x, cfg, run, rules))
    ref_run = dataclasses.replace(run, reference_mode=True)
    f_ref = jax.jit(lambda x, p: L.mlp_block(p, x, cfg, ref_run, rules))
    us_pk = timeit(f_pk, x, p)
    us_ref = timeit(f_ref, x, p)
    row("template_mlp_island/pk", us_pk,
        f"vs_reference={us_ref/max(us_pk,1e-9):.2f}x")
    row("template_mlp_island/reference", us_ref, "")
    for plan in L.island_plans(cfg, run, rules, batch=b, seq=s):
        row(f"template_plan/{plan.island}", 0.0,
            ("fallback:" + plan.reason) if plan.fallback else
            f"backend={plan.backend} chunks={plan.n_chunks} "
            f"hidden={plan.hidden_fraction}")


def fig_chunk_pipeline():
    """Chunk-pipelined ring vs the classic 1-chunk ring at small K (the
    paper-Fig. 2/11 regime: small per-step transfers waste link bandwidth),
    plus the measured per-island overlap plan. The `auto` rows use the chunk
    scheduler's resolution — on the calibrated emulated mesh (expensive
    per-hop sync) it must stay at or below the unchunked ring by picking the
    right count; forced `c4` rows show what over-chunking costs here."""
    mesh = make_mesh()
    ctx = CommContext(axis_name="x", mesh=mesh, policy="auto")
    hw = pred_hw()
    cases = (
        ("gemm_rs", "matmul_reduce_scatter",
         (P(None, "x"), P("x", None)), P("x", None)),
        ("ag_gemm", "all_gather_matmul", (P("x"), P()), P()),
    )
    for tag, op, in_specs, out_specs in cases:
        for nsz in (256, 512):           # small-K rows: K_loc = nsz/8
            if op == "all_gather_matmul":
                x = jax.random.normal(jax.random.PRNGKey(0),
                                      (nsz, nsz // 8), jnp.bfloat16)
                w = jax.random.normal(jax.random.PRNGKey(1),
                                      (nsz // 8, nsz // 4), jnp.bfloat16)
                m, n, k = nsz, nsz // 4, nsz // 8
            else:
                x = jax.random.normal(jax.random.PRNGKey(0),
                                      (nsz, N * (nsz // 8)), jnp.bfloat16)
                w = jax.random.normal(jax.random.PRNGKey(1),
                                      (N * (nsz // 8), nsz // 4),
                                      jnp.bfloat16)
                m, n, k = nsz, nsz // 4, nsz // 8
            auto_c = ctx.gemm_chunk_schedule(op, m, n, k, backend="ring",
                                             dtype_bytes=2)
            pred = cm.chunk_pipeline_cost(
                m, n, k, axis_size=N, sub_chunks=auto_c.n_chunks,
                kind=_OP_KIND[op], hw=hw).total
            # time each DISTINCT resolved chunk count once: when the
            # scheduler resolves to a forced count's program (same compiled
            # schedule), both labels report the same measurement instead of
            # sampling one program's noise twice
            labels = (("ring_c1", 1), ("ring_auto", auto_c.n_chunks),
                      ("ring_c4", 4))
            us_by_count: dict = {}
            for _, nc in labels:
                if nc in us_by_count:
                    continue
                island = Island(
                    f"fig_chunk/{tag}/c{nc}", mesh=mesh, axis="x",
                    inputs={"x": in_specs[0], "w": in_specs[1]},
                    out_specs=out_specs,
                    body=lambda ctx_, x, w, nc=nc, op=op: getattr(ctx_, op)(
                        x, w, backend="ring", n_chunks=nc),
                    comm=Comm(op, m=m, n=n, k=k, backend="ring",
                              n_chunks=nc))
                us_by_count[nc] = timeit(
                    jax.jit(lambda x, w, i=island: i(x=x, w=w)), x, w)
            for label, nc in labels:
                auto = label == "ring_auto"
                row(f"fig_chunk_pipeline/{tag}/{label}/K={k}",
                    us_by_count[nc],
                    f"chunks={nc} ({auto_c.source if auto else 'forced'})",
                    predicted_us=pred * 1e6 if auto else None)
    # the measured per-island plan (island-keyed seed rows when present)
    from repro.configs import get_config
    from repro.configs.base import RunConfig
    from repro.models import layers as L
    from repro.models.sharding import ShardingRules

    mesh2 = make_mesh((1, 8), ("data", "x"))
    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), tp_axis="x", fsdp=False,
                    comm_policy="auto", pk_attn_out_island=True)
    rules = ShardingRules(mesh2, run)
    for isl in (L.mlp_island(cfg, run, rules, 8, 128),
                L.attn_out_island(cfg, run, rules, 8, 128)):
        plan = isl.plan()
        row(f"fig_chunk_pipeline/plan/{plan.island}", 0.0,
            f"backend={plan.backend} chunks={plan.n_chunks} "
            f"hidden={plan.hidden_fraction} src={plan.source}",
            island=isl.island_key)


def fig_fused_chunks():
    """Fused single-kernel chunk sweep: the chunk-pipelined fused Pallas
    GEMM×collectives at sub-chunk counts {1, 2, 4, 8}, all three ops.

    On a real TPU each count is timed (the rows ``calibrate --per-island``
    would also produce); off-TPU the fused kernels cannot run — interpret
    timings would be meaningless — so the rows price the same sweep with
    ``costmodel.fused_pipeline_cost`` and carry ``mode="analytic"``. Either
    way a trailing ``/schedule`` row records the chunk count the dispatch
    layer resolves for each op (``sub_chunks``/``chunks_src`` fields), so
    the artifact shows the decision alongside the sweep that justifies it.
    """
    mesh = make_mesh()
    hw = pred_hw()
    on_tpu = jax.default_backend() == "tpu"
    ctx = CommContext(axis_name="x", mesh=mesh, policy="auto")
    cases = (
        ("ag_gemm", "all_gather_matmul", (P("x"), P()), P()),
        ("gemm_rs", "matmul_reduce_scatter",
         (P(None, "x"), P("x", None)), P("x", None)),
        ("gemm_ar", "matmul_all_reduce", (P(None, "x"), P("x", None)), P()),
    )
    m, n, k = 2048, 512, 256
    for tag, op, in_specs, out_specs in cases:
        kind = _OP_KIND[op]
        for c in (1, 2, 4, 8):
            pred = cm.fused_pipeline_cost(
                m, n, k, axis_size=N, sub_chunks=c, kind=kind,
                hw=hw).total * 1e6
            if not on_tpu:
                row(f"fig_fused_chunks/{tag}/c{c}", pred,
                    "analytic fused_pipeline_cost (fused kernels need TPU)",
                    mode="analytic", sub_chunks=c, dtype_bytes=2)
                continue
            if op == "all_gather_matmul":
                x = jax.random.normal(jax.random.PRNGKey(0), (m, k),
                                      jnp.bfloat16)
            else:
                x = jax.random.normal(jax.random.PRNGKey(0), (m, N * k),
                                      jnp.bfloat16)
            w = jax.random.normal(
                jax.random.PRNGKey(1),
                (k if op == "all_gather_matmul" else N * k, n), jnp.bfloat16)
            island = Island(
                f"fig_fused/{tag}/c{c}", mesh=mesh, axis="x",
                inputs={"x": in_specs[0], "w": in_specs[1]},
                out_specs=out_specs,
                body=lambda ctx_, x, w, c=c, op=op: getattr(ctx_, op)(
                    x, w, backend="fused", n_chunks=c),
                comm=Comm(op, m=m, n=n, k=k, backend="fused", n_chunks=c))
            us = timeit(jax.jit(lambda x, w, i=island: i(x=x, w=w)), x, w)
            row(f"fig_fused_chunks/{tag}/c{c}", us, f"sub_chunks={c}",
                predicted_us=pred, mode="measured", sub_chunks=c,
                dtype_bytes=2)
        sched = ctx.gemm_chunk_schedule(op, m, n, k, backend="fused")
        row(f"fig_fused_chunks/{tag}/schedule", 0.0,
            f"resolved sub_chunks={sched.n_chunks} ({sched.reason})",
            mode="measured" if on_tpu else "analytic",
            sub_chunks=sched.n_chunks, chunks_src=sched.source)


def fig_quant_comm():
    """Quantized wire formats on the ring GEMM×collectives: bf16 payloads vs
    the int8+per-block-scale wire (core.quant), same chunk count, all three
    ops. The int8 rows carry ``wire``/``dtype_bytes`` tags so the regression
    gate compares them against same-dtype baselines, a cost-model prediction
    priced at the on-wire element width (wire_bytes=1 plus the quantize-pass
    term), and the measured max relative error vs the bf16 wire."""
    mesh = make_mesh()
    ctx = CommContext(axis_name="x", mesh=mesh)
    hw = pred_hw()
    nsz, nc = 512, 2
    cases = (
        ("ag_gemm", "all_gather_matmul", (P("x"), P()), P()),
        ("gemm_rs", "matmul_reduce_scatter",
         (P(None, "x"), P("x", None)), P("x", None)),
        ("gemm_ar", "matmul_all_reduce",
         (P(None, "x"), P("x", None)), P()),
    )
    for tag, op, in_specs, out_specs in cases:
        if op == "all_gather_matmul":
            x = jax.random.normal(jax.random.PRNGKey(0),
                                  (nsz, nsz // 8), jnp.bfloat16)
            w = jax.random.normal(jax.random.PRNGKey(1),
                                  (nsz // 8, nsz // 4), jnp.bfloat16)
        else:
            x = jax.random.normal(jax.random.PRNGKey(0),
                                  (nsz, N * (nsz // 8)), jnp.bfloat16)
            w = jax.random.normal(jax.random.PRNGKey(1),
                                  (N * (nsz // 8), nsz // 4), jnp.bfloat16)
        m, n, k = nsz, nsz // 4, nsz // 8
        outs = {}
        for wire, wbytes in (("bf16", 2), ("int8", 1)):
            island = Island(
                f"fig_quant/{tag}/{wire}", mesh=mesh, axis="x",
                inputs={"x": in_specs[0], "w": in_specs[1]},
                out_specs=out_specs,
                body=lambda ctx_, x, w, op=op, wire=wire: getattr(ctx_, op)(
                    x, w, backend="ring", n_chunks=nc, wire=wire),
                comm=Comm(op, m=m, n=n, k=k, backend="ring", n_chunks=nc))
            fn = jax.jit(lambda x, w, i=island: i(x=x, w=w))
            outs[wire] = jnp.asarray(fn(x, w), jnp.float32)
            pred = cm.chunk_pipeline_cost(
                m, n, k, axis_size=N, sub_chunks=nc, kind=_OP_KIND[op],
                hw=hw, wire_bytes=None if wire == "bf16" else 1.0).total
            derived = f"chunks={nc}"
            if wire != "bf16":
                rel = float(jnp.max(jnp.abs(outs[wire] - outs["bf16"]))
                            / (jnp.max(jnp.abs(outs["bf16"])) + 1e-9))
                derived += f" max_rel_err_vs_bf16={rel:.4f}"
            row(f"fig_quant_comm/{tag}/{wire}", timeit(fn, x, w), derived,
                predicted_us=pred * 1e6, wire=wire, dtype_bytes=wbytes)
    # int8-KV capacity: resident sequence slots a fixed HBM budget holds at
    # each cache dtype (per-position bytes include the f32 scale planes)
    from repro.configs import get_config
    from repro.runtime import paging
    cfg = get_config("tinyllama-1.1b").reduced()
    s_max, budget = 128, 4 << 20
    for kv, wbytes in (("bf16", 2), ("int8", 1)):
        bpp = paging._kv_bytes_per_pos(cfg, kv)
        row(f"fig_quant_comm/kv_resident_slots/{kv}", 0.0,
            f"bytes_per_pos={bpp} slots={budget // (bpp * s_max)}",
            cache_layout="slab", wire=kv, dtype_bytes=wbytes)


def fig_serving():
    """Continuous batching vs static batching (tokens/s) on the 8-dev mesh.

    The workload is where continuous batching structurally wins: more
    requests than the decode pool, with *skewed* generation lengths.
    Static batching processes max_batch-sized waves, each decoding until
    its LONGEST member finishes (short members over-decode; their extra
    tokens are waste); the engine retires short requests early and admits
    queued ones into the freed slots. Both paths share the same jitted
    prefill/decode math; both are timed warm (second run). NOTE: on the
    emulated CPU mesh a step costs roughly the same at any batch size, so
    the two rows land between parity and ~1.3x depending on machine state —
    the row tracks the trajectory of both paths, not a fixed ratio (the
    structural win needs per-step cost to scale with occupancy, i.e. real
    hardware). Plan rows record each serving bucket's resolved mlp schedule
    so per-bucket dispatch regressions show in the artifact."""
    import time

    import numpy as np

    from repro.configs.base import ServeConfig
    from repro.launch.serve import build_engine, synthetic_trace

    serve = ServeConfig(max_batch=8, prefill_batch=4, bucket_edges=(8, 16),
                        max_new_tokens=16)
    eng = build_engine("tinyllama-1.1b", reduced=True, mesh_shape=(1, 8),
                       mesh_axes=("data", "model"), serve=serve,
                       comm_policy="auto")
    prompts = synthetic_trace(16, serve, eng.cfg.vocab_size, seed=0)
    # serving-realistic skew: mostly short generations plus a few
    # max-length stragglers — each static wave decodes to ITS longest
    # member, so the stragglers pin entire waves of short requests
    rng = np.random.RandomState(1)
    max_new = [serve.max_new_tokens if rng.rand() < 0.25
               else int(rng.randint(2, 5)) for _ in prompts]
    useful = sum(max_new)

    def run_static():
        for w in range(0, len(prompts), serve.max_batch):
            wave = prompts[w:w + serve.max_batch]
            eng.generate_static(wave, max(max_new[w:w + serve.max_batch]))

    def run_continuous():
        for p, mx in zip(prompts, max_new):
            eng.submit(p, mx)
        eng.run()

    run_static()                        # warm: trace + compile both paths
    t0 = time.perf_counter()
    run_static()
    dt_static = time.perf_counter() - t0
    row("fig_serving/static_batch", dt_static * 1e6 / useful,
        f"useful_tokens={useful}", tokens_per_s=useful / dt_static)

    run_continuous()                    # warm the per-bucket jit cache
    t0 = time.perf_counter()
    run_continuous()
    dt_cont = time.perf_counter() - t0
    row("fig_serving/continuous", dt_cont * 1e6 / useful,
        f"useful_tokens={useful} "
        f"vs_static={dt_static / max(dt_cont, 1e-9):.2f}x",
        tokens_per_s=useful / dt_cont)

    # --- memory-bound trace: paged vs slab at EQUAL cache HBM ------------
    # Short prompts against a long s_max: the slab spends a full
    # bucket+max_new strip per slot, the paged pool only the pages each
    # request touches — so at the same byte budget the paged engine keeps
    # 4x+ the residents (vllm's memory argument, reproduced on the
    # engine's own stats). Slab: 2 slots x 40 padded positions = 10 pages
    # of 8 (page interior striped over the 8-way tp axis).
    slab_mb = ServeConfig(max_batch=2, prefill_batch=2, bucket_edges=(32,),
                          max_new_tokens=4)
    paged_mb = ServeConfig(max_batch=8, prefill_batch=8, bucket_edges=(32,),
                           max_new_tokens=4, cache_layout="paged",
                           page_size=8, n_pages=10, prefill_chunk=16)
    rng = np.random.RandomState(7)
    short = [tuple(int(t) for t in rng.randint(0, eng.cfg.vocab_size, 4))
             for _ in range(8)]
    for serve_mb, layout in ((slab_mb, "slab"), (paged_mb, "paged")):
        e = build_engine("tinyllama-1.1b", reduced=True, mesh_shape=(1, 8),
                        mesh_axes=("data", "model"), serve=serve_mb,
                        comm_policy="auto")
        e.run(short)                    # warm
        t0 = time.perf_counter()
        e.run(short)
        dt = time.perf_counter() - t0
        cs = e.cache_stats()
        toks = 8 * serve_mb.max_new_tokens
        row(f"fig_serving/membound/{layout}", dt * 1e6 / toks,
            f"peak_resident_slots={cs['peak_resident_slots']} "
            f"hbm_bytes={cs['hbm_bytes']} steps={e.stats()['steps']}",
            tokens_per_s=toks / dt, cache_layout=layout)

    for name, bp in eng.bucket_plans.items():
        for plan in bp.plans:
            if plan.island != "mlp":
                continue
            row(f"fig_serving/plan/{name}/{plan.island}", 0.0,
                f"backend={plan.backend} chunks={plan.n_chunks} "
                f"hidden={plan.hidden_fraction} src={plan.source}")


def fig_fleet():
    """Serving fleet (runtime/fleet.py): tokens/s vs replica count, plus
    the kill-one-replica completion-set-invariance trace.

    NOTE: replicas are in-process engines stepped round-robin on ONE
    machine, so on the emulated CPU mesh tokens/s does NOT scale with N —
    every replica shares the same cores and each adds its own jit-cache
    footprint. The replica rows track per-replica-count trajectory (a
    routing/scheduling regression shows as one count degrading relative to
    the others), not a scaling claim; real scaling needs one host per
    replica. The kill row is the correctness trace: a scripted
    drain->kill->rejoin fleet run must complete every request exactly once,
    token-identical to the no-fault run (`identical=True` in the derived
    string; also pinned hard by tests/test_fleet.py)."""
    import time

    from repro.configs.base import FleetConfig, ServeConfig
    from repro.launch.serve import build_engine, synthetic_trace
    from repro.runtime.fleet import FaultPlan, ServingFleet

    serve = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8, 16),
                        max_new_tokens=8)

    def factory(i):
        return build_engine("tinyllama-1.1b", reduced=True, serve=serve)

    trace = synthetic_trace(12, serve, 64, seed=2)
    useful = 12 * serve.max_new_tokens

    ref2 = None                          # fleet-of-2 tokens, kill-row ref
    for n in (1, 2, 4):
        fleet = ServingFleet(factory, FleetConfig(n_replicas=n))
        fleet.run(trace)                 # warm every replica's jit cache
        t0 = time.perf_counter()
        out = fleet.run(trace)
        dt = time.perf_counter() - t0
        st = fleet.stats()
        if n == 2:
            ref2 = {c.rid - out[0].rid: tuple(c.tokens) for c in out}
        row(f"fig_fleet/replicas/{n}", dt * 1e6 / useful,
            f"useful_tokens={useful} steals={st['steals']} "
            f"assignments={st['assignments']}",
            tokens_per_s=useful / dt)

    # kill-one-replica trace: cold run (the fault plan scripts absolute
    # fleet steps, so no warm pass), checked token-for-token against the
    # warm no-fault fleet-of-2 run above
    plan = FaultPlan.parse("drain:1@1 kill:1@3 rejoin:1@6")
    fleet = ServingFleet(factory, FleetConfig(n_replicas=2),
                         fault_plan=plan)
    t0 = time.perf_counter()
    out = fleet.run(trace)
    dt = time.perf_counter() - t0
    got = {c.rid: tuple(c.tokens) for c in out}
    identical = got == ref2 and len(out) == len(trace)
    st = fleet.stats()
    row("fig_fleet/kill_one", dt * 1e6 / useful,
        f"identical={identical} requeued={st['requeued']} "
        f"completed={st['completed']} live={st['live']} (cold run)",
        tokens_per_s=useful / dt)
    if not identical:
        raise AssertionError(
            "kill-one-replica run diverged from the no-fault completion set")


def fig_health():
    """Runtime health (runtime/health.py): serving throughput under a
    scripted sustained link stall, three conditions tagged by ``mode``:

    * ``healthy``      — no faults (the baseline the others gate against);
    * ``degraded``     — stall + HealthMonitor ON: the mlp island demotes
                         to bulk after the hysteresis window, so only the
                         first few steps eat the stall;
    * ``hard_failure`` — same stall, monitor OFF: every prefill step eats
                         the stall for the fault's whole duration.

    Stalls inflate *recorded* step times (synthetic, reproducible — no
    sleeps), so rows report the engine's own ``stats()`` wall: the
    degraded/hard_failure ratio is the monitor's measured win. The
    quarantine row rides along: a corrupt ring hop with guards on must
    quarantine the poisoned requests and complete the rest."""
    import numpy as np

    from repro.configs.base import ServeConfig
    from repro.launch.serve import build_engine
    from repro.runtime.health import CommFaultEvent, CommFaultPlan

    # max_new_tokens=1 makes every step a prefill — the phase whose mlp
    # plan is ring-family on this mesh, i.e. where a link stall can bite
    def mk(serve, faults=None):
        return build_engine(
            "tinyllama-1.1b", reduced=True, mesh_shape=(1, 8),
            mesh_axes=("data", "model"), serve=serve,
            run_overrides={"comm_backend": "ring"}, comm_faults=faults)

    def trace(n=16):
        rng = np.random.RandomState(0)
        return [tuple(int(t) for t in rng.randint(1, 64, size=5))
                for _ in range(n)]

    stall = CommFaultPlan(events=(
        CommFaultEvent("stall", "mlp", 3, ticks=6, stall_dt=50.0),))
    base = dict(max_batch=4, prefill_batch=2, bucket_edges=(8,),
                max_new_tokens=1)
    runs = [
        ("healthy", ServeConfig(**base, health_monitor=True), None),
        ("degraded", ServeConfig(**base, health_monitor=True,
                                 health_demote_after=2,
                                 health_probation=4), stall),
        ("hard_failure", ServeConfig(**base), stall),
    ]
    for mode, serve, faults in runs:
        eng = mk(serve, faults)
        done = eng.run(trace())
        st = eng.stats()
        toks = len(done)
        row(f"fig_health/stall/{mode}", st["wall_s"] * 1e6 / toks,
            f"demotions={st['health_demotions']} "
            f"stragglers={st['straggler_events']} steps={st['steps']}",
            tokens_per_s=st["tokens_per_s"], mode=mode)
        if mode == "degraded" and st["health_demotions"] < 1:
            raise AssertionError("stall never triggered a health demotion")

    # corrupt ring hop: guards catch the NaN, poisoned requests quarantine,
    # the rest complete (tests pin bit-identity; the row tracks counts)
    serve = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8,),
                        max_new_tokens=4, max_retries=0)
    eng = build_engine(
        "tinyllama-1.1b", reduced=True, mesh_shape=(1, 8),
        mesh_axes=("data", "model"), serve=serve,
        run_overrides={"comm_backend": "ring", "island_guards": True},
        comm_faults="corrupt:mlp@1")
    done = eng.run(trace(4))
    st = eng.stats()
    row("fig_health/quarantine", st["wall_s"] * 1e6 / max(len(done), 1),
        f"completed={len(done)} quarantined={st['quarantined']} "
        f"guard_trips={st['guard_trips']}", mode="hard_failure")
    if st["quarantined"] == 0 or not done:
        raise AssertionError(
            "corrupt hop did not quarantine, or starved all survivors")


ALL = [fig2_3_transfer_granularity, table3_hiding_threshold,
       fig6_allreduce_design_overhead, fig7_ag_gemm, fig8_gemm_rs,
       fig9_gemm_ar, fig10_ring_attention, fig11_ulysses, fig12_moe_dispatch,
       fig15_17_strided_collectives, fig_unified_template,
       fig_chunk_pipeline, fig_fused_chunks, fig_quant_comm, fig_serving,
       fig_fleet, fig_health]
