"""Shared benchmark plumbing: 8 emulated devices, timing, result recording.

CPU wall-times are *relative* indicators (the interconnect is emulated);
the hardware-grounded numbers live in the roofline analysis
(results/dryrun + EXPERIMENTS.md). Two outputs per run:

* CSV on stdout, one row per measurement: ``figure,name,us_per_call,derived``
  (the figure column appears on every row — including failure rows — so a
  partial run is diagnosable from the artifact alone);
* ``BENCH_comms.json`` (schema ``repro-bench/v1``), written by
  ``benchmarks/run.py`` from the module-level ``RECORDER``: per figure the
  rows, status, and the predicted-vs-measured error of the §3.1.1 cost model
  wherever a bench supplies a prediction. ``scripts/check_bench.py``
  validates it and gates regressions vs ``benchmarks/BENCH_baseline.json``.

Predictions use ``pred_hw()`` — the calibrated spec when a
``repro.core.autotune`` table matches this machine (the in-repo
``cpu_emulated`` seed covers the emulated mesh), the analytic v5e constants
otherwise — so the reported model error is meaningful on CPU too.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
from jax.sharding import PartitionSpec as P  # noqa: F401

from repro import compat

SCHEMA = "repro-bench/v1"


def make_mesh(shape=(8,), axes=("x",)):
    return compat.make_mesh(shape, axes)


def smap(mesh, fn, in_specs, out_specs):
    return jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                    out_specs=out_specs, check_vma=False))


def timeit(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


class Recorder:
    """Collects every ``row(...)`` under the figure currently running."""

    def __init__(self):
        self.figures: list[dict] = []
        self._cur: dict | None = None

    def start_figure(self, name: str) -> None:
        self._cur = {"figure": name, "status": "ok", "error": None,
                     "rows": []}
        self.figures.append(self._cur)

    def fail(self, exc: BaseException) -> None:
        if self._cur is not None:
            self._cur["status"] = "failed"
            self._cur["error"] = f"{type(exc).__name__}: {exc}"

    @property
    def current_figure(self) -> str:
        return self._cur["figure"] if self._cur else "-"

    def add(self, name: str, us: float, derived: str,
            predicted_us: float | None,
            island: str | None = None,
            tokens_per_s: float | None = None,
            cache_layout: str | None = None,
            wire: str | None = None,
            dtype_bytes: int | None = None,
            mode: str | None = None,
            sub_chunks: int | None = None,
            chunks_src: str | None = None) -> None:
        err = None
        if predicted_us is not None and us > 0:
            err = (predicted_us - us) / us
        if self._cur is None:          # bench module run outside the harness
            self.start_figure("-")
        self._cur["rows"].append({
            "name": name, "us_per_call": us, "derived": derived,
            "predicted_us": predicted_us, "pred_err": err,
            "island": island, "tokens_per_s": tokens_per_s,
            "cache_layout": cache_layout,
            "wire": wire, "dtype_bytes": dtype_bytes, "mode": mode,
            "sub_chunks": sub_chunks, "chunks_src": chunks_src,
        })

    def report(self) -> dict:
        figures = []
        for fig in self.figures:
            errs = sorted(abs(r["pred_err"]) for r in fig["rows"]
                          if r["pred_err"] is not None)
            figures.append({
                **fig,
                "n_rows": len(fig["rows"]),
                "pred_err_median": errs[len(errs) // 2] if errs else None,
            })
        from repro.launch.mesh import device_fingerprint
        return {
            "schema": SCHEMA,
            "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "jax_version": jax.__version__,
            **device_fingerprint(),
            "pred_hw": pred_hw().name
            + ("" if _pred_table() is None else " (calibrated)"),
            "figures": figures,
        }


RECORDER = Recorder()


def row(name: str, us: float, derived: str = "",
        predicted_us: float | None = None, island: str | None = None,
        tokens_per_s: float | None = None, cache_layout: str | None = None,
        wire: str | None = None, dtype_bytes: int | None = None,
        mode: str | None = None, sub_chunks: int | None = None,
        chunks_src: str | None = None):
    """One measurement: prints the CSV row and records it for the JSON
    artifact. ``predicted_us`` is the §3.1.1 cost-model prediction for the
    same configuration (on ``pred_hw()``) when the bench can supply one;
    ``island`` tags rows that belong to one island's calibration key
    (``repro.core.autotune.island_key``); ``tokens_per_s`` carries serving
    throughput (fig_serving) so the regression gate sees it as data, not
    just a derived string; ``cache_layout`` tags the KV layout
    ("slab"/"paged") behind a serving row; ``wire``/``dtype_bytes`` tag the
    on-wire element format of a quantized-collective row (fig_quant_comm)
    so dtype regressions gate against same-dtype baselines only; ``mode``
    tags a runtime-health row's serving condition (fig_health:
    "healthy" / "degraded" / "hard_failure") so the gate compares
    like-for-like fault scenarios, and the fused chunk sweep's cost source
    ("measured" on TPU, "analytic" off it); ``sub_chunks``/``chunks_src``
    tag a chunk-pipeline row with the sub-chunk count it ran (or priced)
    and where the resolved count came from (``ChunkSchedule.source``)."""
    print(f"{RECORDER.current_figure},{name},{us:.1f},{derived}")
    RECORDER.add(name, us, derived, predicted_us, island, tokens_per_s,
                 cache_layout, wire, dtype_bytes, mode, sub_chunks,
                 chunks_src)


def _pred_table():
    from repro.core import autotune
    return autotune.resolve_table(None, "tpu_v5e", "auto")


def pred_hw():
    """HardwareSpec predictions are priced on: calibrated when a table
    matches this machine, the analytic v5e constants otherwise."""
    from repro.core import costmodel as cm
    table = _pred_table()
    return table.spec(cm.TPU_V5E) if table is not None else cm.TPU_V5E
