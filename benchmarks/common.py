"""Shared benchmark plumbing: 8 emulated devices, timing, CSV output.

CPU wall-times are *relative* indicators (the interconnect is emulated);
the hardware-grounded numbers live in the roofline analysis
(results/dryrun + EXPERIMENTS.md). Each bench prints
``name,us_per_call,derived`` rows per the harness contract.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P  # noqa: F401

from repro import compat


def make_mesh(shape=(8,), axes=("x",)):
    return compat.make_mesh(shape, axes)


def smap(mesh, fn, in_specs, out_specs):
    return jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                    out_specs=out_specs, check_vma=False))


def timeit(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
