"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--json BENCH_comms.json]
                                            [--figures fig7,fig8] [--list]

Prints ``figure,name,us_per_call,derived`` CSV to stdout (failure rows
included, with the figure name, so partial runs are diagnosable) and writes
the machine-readable ``BENCH_comms.json`` (schema ``repro-bench/v1``:
per-figure rows, status, predicted-vs-measured cost-model error).
``scripts/check_bench.py`` validates the artifact and fails on >25%
regression vs ``benchmarks/BENCH_baseline.json`` (the ``make bench`` gate).

CPU wall-times are relative (emulated interconnect); hardware-grounded
numbers are in the roofline analysis (EXPERIMENTS.md §Roofline).
"""

import argparse
import json
import sys
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.run")
    ap.add_argument("--json", default="BENCH_comms.json",
                    help="machine-readable artifact path ('' disables)")
    ap.add_argument("--figures", default="",
                    help="comma-separated substrings selecting figures")
    ap.add_argument("--list", action="store_true",
                    help="list figure names and exit")
    args = ap.parse_args(argv)

    from benchmarks import paper_figures
    from benchmarks.common import RECORDER

    wanted = [s for s in args.figures.split(",") if s]
    figures = [fn for fn in paper_figures.ALL
               if not wanted or any(w in fn.__name__ for w in wanted)]
    if args.list:
        for fn in figures:
            print(fn.__name__)
        return 0

    print("figure,name,us_per_call,derived")
    failures = 0
    for fn in figures:
        RECORDER.start_figure(fn.__name__)
        try:
            fn()
        except Exception as e:
            failures += 1
            RECORDER.fail(e)
            # the failure lands in the CSV *with* the figure name (and in
            # the JSON), not just on stderr — a partial run's artifact says
            # what broke. JAX errors routinely contain commas/newlines;
            # flatten them so the row stays one parseable CSV record.
            msg = f"{type(e).__name__}: {e}"
            msg = " ".join(msg.split()).replace(",", ";")[:160]
            print(f"{fn.__name__},BENCH_FAILED,,{msg}")
            traceback.print_exc()

    if args.json:
        doc = RECORDER.report()
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        ok = sum(1 for g in doc["figures"] if g["status"] == "ok")
        print(f"# wrote {args.json}: {ok}/{len(doc['figures'])} figures ok",
              file=sys.stderr)
    return 1 if failures else 0


if __name__ == '__main__':
    raise SystemExit(main())
