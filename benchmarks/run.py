# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
# CPU wall-times are relative (emulated interconnect); hardware-grounded
# numbers are in the roofline analysis (EXPERIMENTS.md §Roofline).
import sys
import traceback


def main() -> None:
    from benchmarks import paper_figures
    print("name,us_per_call,derived")
    failures = 0
    for fn in paper_figures.ALL:
        try:
            fn()
        except Exception:
            failures += 1
            print(f"BENCH_FAILED,{fn.__name__},", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
